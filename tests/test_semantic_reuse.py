"""Semantic cache reuse: rewrite correctness and scan-byte savings.

Acceptance invariants for the reuse layer:

  * with ``reuse="on"``, a repeated/overlapping workload returns answers
    (match counts) identical to the reuse-off path while scanning strictly
    fewer raw bytes;
  * ``reuse="off"`` is the default and leaves every reuse counter at zero
    (seed parity itself is pinned by ``tests/test_policy_parity.py``);
  * covered sub-regions are served by slicing resident chunks in place,
    shipping only the sliced extent.
"""
import tempfile

import numpy as np
import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import GeneratedFile, make_ptf_files
from repro.core.cluster import RawArrayCluster, workload_summary
from repro.core.coordinator import SimilarityJoinQuery
from repro.core.geometry import Box, bounding_box
from repro.core.workload import ptf2_workload

N_NODES = 4


def handcrafted_dataset(tmp_prefix="reuse_"):
    """One file: a dense 10x10 block at the origin plus two far outliers
    whose tight bounding box still overlaps queries near the block. A
    second disjoint file keeps the catalog non-trivial."""
    dense = np.array([(x, y) for x in range(10) for y in range(10)],
                     dtype=np.int64)
    outliers = np.array([(15, 50), (50, 15)], dtype=np.int64)
    coords0 = np.concatenate([dense, outliers])
    coords1 = np.array([(x, y) for x in range(80, 90)
                        for y in range(80, 90)], dtype=np.int64)
    files = []
    for coords in (coords0, coords1):
        attrs = np.zeros((coords.shape[0], 1), dtype=np.float32)
        files.append(GeneratedFile(coords, attrs, bounding_box(coords)))
    return build_catalog(files, tempfile.mkdtemp(prefix=tmp_prefix),
                         "fits", n_nodes=N_NODES)


def make_cluster(catalog, data, reuse, policy="cost", budget=10**7,
                 min_cells=8):
    return RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                           budget, policy=policy, min_cells=min_cells,
                           reuse=reuse)


def run(cluster, queries):
    executed = cluster.run_workload(queries)
    return executed, workload_summary(executed)


# ------------------------------------------------------------ guard rails

def test_reuse_off_is_default_and_counts_nothing():
    catalog, data = handcrafted_dataset()
    cluster = make_cluster(catalog, data, reuse="off")
    assert cluster.coordinator.reuse == "off"
    default = RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                              10**7)
    assert default.coordinator.reuse == "off"
    queries = [SimilarityJoinQuery(Box((0, 0), (9, 9)), eps=1)] * 3
    executed, summary = run(cluster, queries)
    assert all(v == 0 for k, v in cluster.coordinator.stats.items())
    assert summary["reuse_hits"] == 0
    assert summary["reuse_bytes_served"] == 0


def test_unknown_reuse_mode_rejected():
    catalog, data = handcrafted_dataset()
    with pytest.raises(ValueError, match="reuse"):
        make_cluster(catalog, data, reuse="maybe")


# -------------------------------------------- the handcrafted skip scenario

def test_covered_query_skips_rescan_with_identical_answers():
    """Q2 overlaps an uncached leaf's bounding box but every actually
    queried cell lives in covering cached chunks: reuse-off rescans the
    file, reuse-on serves the sub-region from cache."""
    q1 = SimilarityJoinQuery(Box((0, 0), (9, 9)), eps=1)
    q2 = SimilarityJoinQuery(Box((5, 5), (20, 20)), eps=1)
    results = {}
    for reuse in ("off", "on"):
        catalog, data = handcrafted_dataset()
        cluster = make_cluster(catalog, data, reuse=reuse)
        executed, summary = run(cluster, [q1, q2])
        results[reuse] = (executed, summary, dict(cluster.coordinator.stats))
    ex_off, s_off, _ = results["off"]
    ex_on, s_on, stats = results["on"]

    # Identical answers...
    matches_off = [e.matches for e in ex_off]
    matches_on = [e.matches for e in ex_on]
    assert matches_on == matches_off
    assert matches_on[1] > 0            # Q2 actually joins dense cells
    # ...with strictly fewer raw bytes scanned.
    assert s_on["bytes_scanned"] < s_off["bytes_scanned"]

    r2_off, r2_on = ex_off[1].report, ex_on[1].report
    assert sum(r2_off.scan_bytes_by_node.values()) > 0   # off: rescan
    assert sum(r2_on.scan_bytes_by_node.values()) == 0   # on: served
    assert r2_on.reuse_scan_skips == 1
    # Soundness of the skip: the scan-free admission touches only chunks
    # served from resident coverage — every queried chunk is a reuse hit
    # and every queried cell was shipped as a slice ("cached implies
    # scanned" is never violated by a skip).
    assert r2_on.reuse_hits == len(r2_on.queried_chunks)
    cell_bytes = catalog.by_id(0).cell_bytes
    assert r2_on.reuse_bytes_served == r2_on.queried_cells * cell_bytes
    assert r2_on.reuse_hits > 0
    assert r2_on.reuse_bytes_served > 0
    assert r2_on.residual_bytes_scanned == 0
    assert stats["reuse_scan_skips"] == 1
    assert stats["reuse_hits"] > 0


def test_repeated_query_serves_slices_from_cache():
    """Same query twice: the second admission is served entirely from
    covering cached chunks (box-level full coverage + slice hits)."""
    catalog, data = handcrafted_dataset()
    cluster = make_cluster(catalog, data, reuse="on")
    q = SimilarityJoinQuery(Box((0, 0), (9, 9)), eps=1)
    first = cluster.run_query(q)
    second = cluster.run_query(q)
    assert sum(first.report.scan_bytes_by_node.values()) > 0
    assert sum(second.report.scan_bytes_by_node.values()) == 0
    assert second.report.reuse_hits > 0
    assert second.report.reuse_bytes_served > 0
    assert second.report.reuse_fully_covered
    assert second.matches == first.matches


def test_sliced_shipping_charges_at_most_chunk_bytes():
    """Shipped bytes for covered slices never exceed the resident chunks'
    full size, and the sliced extent matches the queried cell count."""
    catalog, data = handcrafted_dataset()
    cluster = make_cluster(catalog, data, reuse="on")
    q = SimilarityJoinQuery(Box((0, 0), (9, 9)), eps=1)
    cluster.run_query(q)
    report = cluster.run_query(q).report
    full = sum(cm.nbytes for cm in report.queried_chunks)
    assert 0 < report.reuse_bytes_served <= full
    cell_bytes = catalog.by_id(0).cell_bytes
    assert report.reuse_bytes_served == report.queried_cells * cell_bytes


# ------------------------------------------------- workload-level savings

def parity_dataset():
    """The fixed-seed dataset of ``tests/test_policy_parity.py`` — an
    overlapping workload with known-positive join matches."""
    files = make_ptf_files(n_files=10, cells_per_file_mean=900, seed=21)
    return build_catalog(files, tempfile.mkdtemp(prefix="ptf_"), "fits",
                         n_nodes=N_NODES)


def parity_workload(catalog, repeats=2):
    from repro.core.workload import ptf1_workload
    base = (ptf1_workload(catalog.domain, n_queries=4, eps=300, seed=7)
            + ptf2_workload(catalog.domain, n_queries=4, eps=300))
    return base * repeats


@pytest.mark.parametrize("policy", ["cost", "chunk_lru"])
def test_overlapping_workload_scans_strictly_fewer_bytes(policy):
    """On the repeated PTF overlapping workload the reuse path returns the
    same match counts as reuse-off while scanning strictly fewer bytes."""
    catalog, data = parity_dataset()
    queries = parity_workload(catalog)
    out = {}
    for reuse in ("off", "on"):
        cluster = make_cluster(catalog, data, reuse=reuse, policy=policy,
                               budget=6_000, min_cells=64)
        executed, summary = run(cluster, queries)
        out[reuse] = ([e.matches for e in executed], summary)
    matches_off, s_off = out["off"]
    matches_on, s_on = out["on"]
    assert matches_on == matches_off
    assert sum(m for m in matches_on if m) > 0
    assert s_on["bytes_scanned"] < s_off["bytes_scanned"]
    assert s_on["reuse_hits"] > 0
    assert s_on["reuse_bytes_served"] > 0
    assert s_on["residual_bytes_scanned"] == s_on["bytes_scanned"]


def test_file_granularity_slices_resident_units():
    """file_lru under reuse: resident whole-file units are sliced to the
    query extent, cutting shipped bytes while answers stay identical."""
    catalog, data = parity_dataset()
    total = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    queries = parity_workload(catalog, repeats=1)
    out = {}
    for reuse in ("off", "on"):
        cluster = make_cluster(catalog, data, reuse=reuse, policy="file_lru",
                               budget=4 * total)   # everything stays resident
        executed, summary = run(cluster, queries)
        net = sum(sum(e.report.join_plan.bytes_in.values())
                  for e in executed if e.report.join_plan)
        out[reuse] = ([e.matches for e in executed], summary, net)
    matches_off, _, net_off = out["off"]
    matches_on, s_on, net_on = out["on"]
    assert matches_on == matches_off
    assert s_on["reuse_hits"] > 0
    assert s_on["reuse_bytes_served"] > 0
    assert net_on <= net_off
    # Scan bytes are untouched at file granularity (no finer extents).
    assert s_on["bytes_scanned"] == out["off"][1]["bytes_scanned"]
