"""Serving: paged KV cache manager (cost vs LRU), prefix sharing, replica
placement, and the end-to-end engine."""
import jax
import numpy as np
import pytest

from repro.configs import get, reduced
from repro.models.model import init_params
from repro.serve.engine import Request, ServingEngine
from repro.serve.kvcache import PagedKVCacheManager, _prefix_hashes


def mk(policy="cost", pages=8, page_size=4, page_bytes=100):
    return PagedKVCacheManager(page_size=page_size,
                               budget_bytes=pages * page_bytes,
                               page_bytes=page_bytes, policy=policy)


def test_prefix_hashes_are_prefix_closed():
    a = _prefix_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = _prefix_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4)
    assert a[0] == b[0] and a[1] != b[1]


def test_shared_prefix_hits():
    m = mk()
    system = list(range(16))
    r1 = m.allocate(1, system + [100, 101, 102, 103])
    assert r1.hit_pages == 0
    r2 = m.allocate(2, system + [200, 201, 202, 203])
    assert r2.hit_pages == 4          # the shared 16-token prefix
    assert r2.recompute_tokens == 4


def test_miss_inside_prefix_forces_full_recompute():
    m = mk(pages=4)
    toks = list(range(32))            # 8 pages, budget 4
    r = m.allocate(1, toks)
    assert r.recompute_tokens >= 16   # early pages evicted -> no usable prefix
    r2 = m.allocate(2, toks)
    # Whatever is resident, usable prefix stops at the first hole.
    assert 0 <= r2.recompute_tokens <= 32


def test_cost_policy_keeps_hot_system_prompt():
    """A hot shared prefix + cold one-off requests: cost-based keeps the
    shared pages; hit rate must beat LRU."""
    rng = np.random.default_rng(0)
    system = list(range(24))          # 6 pages

    def run(policy):
        m = mk(policy=policy, pages=10, page_size=4)
        hits = 0
        total = 0
        for i in range(30):
            if i % 2 == 0:
                toks = system + rng.integers(100, 200, 8).tolist()
            else:    # cold scans that try to flush the cache
                toks = rng.integers(1000 + 100 * i, 1000 + 100 * i + 99,
                                    28).tolist()
            r = m.allocate(i, toks)
            if i % 2 == 0:
                hits += r.hit_pages
                total += len(r.page_ids)
        return hits / max(total, 1)

    assert run("cost") >= run("lru")
    assert run("cost") > 0.3


def test_replica_placement_colocates_shared_pages():
    m = mk(pages=16, page_size=4)
    system = list(range(16))
    for i in range(4):
        m.allocate(i, system + [300 + i])
    loc = m.assign_replica_groups(n_groups=2, group_budget_bytes=1600)
    shared = _prefix_hashes(system, 4)
    shared_ids = [m.by_key[k].page_id for k in shared]
    groups = {loc[p] for p in shared_ids if p in loc}
    assert len(groups) == 1           # all shared pages on one group


def test_engine_end_to_end():
    cfg = reduced(get("qwen1.5-0.5b"), d_model=32, n_periods=1, vocab=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, slots=2, max_len=64, page_size=4,
                           cache_budget_pages=64)
    system = list(range(1, 13))
    reqs = [Request(request_id=i, prompt=system + [20 + i],
                    max_new_tokens=4) for i in range(4)]
    done = engine.run(reqs)
    assert len(done) == 4
    assert all(len(r.generated) == 4 for r in done)
    st = engine.stats
    assert st.prefill_saved > 0       # later requests reuse the system pages
    # Identical prompts decode identical first tokens (batch consistency).
    reqs2 = [Request(request_id=10 + i, prompt=system + [99],
                     max_new_tokens=2) for i in range(2)]
    done2 = engine.run(reqs2)
    assert done2[0].generated == done2[1].generated
