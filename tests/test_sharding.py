"""Partition rules: TP/FSDP spec assignment, divisibility degradation, and
a small end-to-end sharded train step on a host mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get, reduced
from repro.launch.mesh import auto_axis_kwargs
from repro.models.model import init_params
from repro.sharding.partition import (ShardingPolicy, make_policy,
                                      param_specs)


def host_mesh(shape=(1, 1), axes=("data", "model")):
    n = len(jax.devices())
    return jax.make_mesh((1, n), axes, **auto_axis_kwargs(2))


def test_tp_specs_for_attention_and_mlp():
    cfg = get("llama3.2-3b")
    mesh = host_mesh()
    policy = ShardingPolicy(dp_axes=("data",), fsdp=False)
    aps = jax.eval_shape(lambda: init_params(reduced(cfg),
                                             jax.random.PRNGKey(0)))
    specs = param_specs(aps, mesh, policy)
    b0 = specs["blocks"]["b0"]
    assert b0["mixer"]["wq"] == P(None, None, "model")   # stacked + column
    assert b0["mixer"]["wo"] == P(None, "model", None)   # row-parallel
    assert b0["mlp"]["w_in"] == P(None, None, "model")
    assert b0["mlp"]["w_out"] == P(None, "model", None)
    assert specs["final_norm"]["scale"] == P(None)


def test_fsdp_adds_dp_axis():
    cfg = reduced(get("llama3.2-3b"), d_model=64)
    mesh = host_mesh()
    policy = ShardingPolicy(dp_axes=("data",), fsdp=True)
    aps = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(aps, mesh, policy)
    wq = specs["blocks"]["b0"]["mixer"]["wq"]
    assert wq == P(None, ("data",), "model")


def test_indivisible_dims_degrade_to_replication():
    # internvl2 vocab 92553 is not divisible by any multi-device axis.
    cfg = get("internvl2-2b")
    mesh = jax.make_mesh((1, len(jax.devices())), ("data", "model"),
                         **auto_axis_kwargs(2))
    policy = ShardingPolicy(dp_axes=("data",), fsdp=False)
    aps = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(aps, mesh, policy)
    if mesh.shape["model"] > 1 and cfg.vocab_size % mesh.shape["model"]:
        assert specs["embed"]["table"][0] is None


def test_policy_thresholds():
    mesh = host_mesh()
    small = make_policy(get("qwen1.5-0.5b"), mesh)
    big = make_policy(get("nemotron-4-340b"), mesh)
    assert not small.fsdp and big.fsdp


def test_moe_expert_axis_sharded():
    cfg = get("deepseek-moe-16b")
    mesh = host_mesh()
    policy = ShardingPolicy(dp_axes=("data",), fsdp=False)
    aps = jax.eval_shape(lambda: init_params(reduced(cfg),
                                             jax.random.PRNGKey(0)))
    specs = param_specs(aps, mesh, policy)
    w_in = specs["blocks"]["b0"]["mlp"]["w_in"]
    assert w_in[1] == "model" or w_in[1] is None  # E axis (after stack dim)
