"""Block-sparse simjoin parity and effectiveness: the pruned
(``PrefetchScalarGridSpec``) kernel path must count exactly what the
dense grid counts — on random and clustered coordinates, across eps=0,
self-join dedup, and sentinel-padding edges — while evaluating a
fraction of the block pairs on clustered inputs, without retracing
across repeated same-shape dispatches, on both execution backends
(the CI ``tier1-mesh`` job reruns this file under 4 virtual devices)."""
import tempfile

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.backend.executors import (NumpyJoinExecutor,  # noqa: E402
                                     PallasJoinExecutor,
                                     count_similar_pairs_np,
                                     make_join_executor)
from repro.kernels.simjoin import ops, prune  # noqa: E402
from repro.kernels.simjoin.ref import count_pairs_ref  # noqa: E402
from repro.kernels.simjoin.simjoin import BLOCK  # noqa: E402


def uniform_coords(rng, n, d, hi=500):
    return rng.integers(0, hi, size=(n, d)).astype(np.int32)


def clustered_coords(rng, n, d, n_clusters=6, domain=50_000, spread=30):
    centers = rng.integers(0, domain, (n_clusters, d))
    pick = rng.integers(0, n_clusters, n)
    return (centers[pick] + rng.integers(-spread, spread + 1,
                                         (n, d))).astype(np.int32)


# ------------------------------------------------------- kernel parity

@pytest.mark.parametrize("n,m", [(1, 1), (7, 13), (128, 128), (130, 255),
                                 (300, 41), (1024, 77)])
@pytest.mark.parametrize("maker", [uniform_coords, clustered_coords])
def test_pruned_cross_join_matches_ref(n, m, maker):
    rng = np.random.default_rng(n * 1000 + m)
    a = maker(rng, n, 3)
    b = maker(rng, m, 3)
    for eps in (0, 1, 3, 50):
        got, total, evaluated = ops.count_similar_pairs_pruned_np(
            a, b, eps, False)
        want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(b), eps,
                                   False))
        assert got == want, (n, m, eps, maker.__name__)
        assert evaluated <= total


@pytest.mark.parametrize("n", [1, 5, 129, 384, 1000])
@pytest.mark.parametrize("maker", [uniform_coords, clustered_coords])
def test_pruned_self_join_matches_ref(n, maker):
    rng = np.random.default_rng(n)
    a = maker(rng, n, 3)
    for eps in (0, 1, 2):
        got, _, _ = ops.count_similar_pairs_pruned_np(a, a, eps, True)
        want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(a), eps,
                                   True))
        assert got == want, (n, eps, maker.__name__)


@pytest.mark.parametrize("n", [BLOCK - 1, BLOCK, BLOCK + 1, 2 * BLOCK,
                               2 * BLOCK + 7])
def test_sentinel_padding_edges(n):
    """Sizes straddling the BLOCK boundary: sentinel-padded tail cells
    must not join, with boxes built from real cells only."""
    rng = np.random.default_rng(n)
    a = clustered_coords(rng, n, 2)
    b = clustered_coords(rng, n + 3, 2)
    for same in (False, True):
        bb = a if same else b
        got, _, _ = ops.count_similar_pairs_pruned_np(a, bb, 5, same)
        want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(bb), 5,
                                   same))
        assert got == want


def test_duplicate_coords_self_join_dedup():
    """eps=0 self-join over duplicated cells: each unordered duplicate
    pair counts once, across the sorted order and block boundaries."""
    base = np.array([[10, 10], [10, 10], [10, 10], [99, 1]], np.int32)
    a = np.repeat(base, 80, axis=0)          # 320 cells, 3 blocks
    got, _, _ = ops.count_similar_pairs_pruned_np(a, a, 0, True)
    want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(a), 0, True))
    assert got == want


def test_pruning_skips_blocks_on_clustered():
    rng = np.random.default_rng(0)
    a = clustered_coords(rng, 4096, 3, n_clusters=12, domain=100_000)
    _, total, evaluated = ops.count_similar_pairs_pruned_np(a, a, 64, True)
    assert total == (4096 // BLOCK) ** 2
    assert evaluated <= total // 2, (evaluated, total)


def test_prune_helpers():
    rng = np.random.default_rng(3)
    a = clustered_coords(rng, 300, 3)
    s = prune.spatial_sort(a)
    assert sorted(map(tuple, s)) == sorted(map(tuple, a))  # permutation
    lo, hi = prune.block_bounds(s, BLOCK)
    assert lo.shape == hi.shape == (3, 3)
    assert (lo <= hi).all()
    assert prune.padded_pair_len(1) == 8
    assert prune.padded_pair_len(9) == 16
    padded = prune.pad_pairs(np.ones((3, 3), np.int32), 8)
    assert padded.shape == (8, 3) and (padded[3:] == 0).all()


def test_pad_pairs_oversize_raises_value_error():
    """An oversize pair list is a ValueError with the shapes in the
    message — not a bare assert, which vanishes under ``python -O`` and
    would let silent truncation drop matches."""
    with pytest.raises(ValueError, match=r"\(9, 3\).*8"):
        prune.pad_pairs(np.ones((9, 3), np.int32), 8)


def test_spatial_sort_lexicographic_tiebreak():
    """Equal primary-key runs are ordered lexicographically over the
    remaining dimensions, so duplicate-key cells land in adjacent
    (tighter) blocks; the output stays a permutation of the input."""
    rng = np.random.default_rng(8)
    # dim 0 has the largest span but only 3 distinct values: long
    # equal-key runs exercise the tie-break.
    a = np.stack([rng.choice([0, 5_000, 10_000], 500),
                  rng.integers(0, 40, 500),
                  rng.integers(0, 40, 500)], axis=1).astype(np.int32)
    s = prune.spatial_sort(a)
    assert sorted(map(tuple, s)) == sorted(map(tuple, a))
    # Full ordering: primary key, then the remaining dims in ascending
    # dimension order.
    keyed = [(int(r[0]), int(r[1]), int(r[2])) for r in s]
    assert keyed == sorted(keyed)


# ----------------------------------------------------- executor parity

def make_tasks(rng, k=8):
    tasks = []
    for i in range(k):
        a = clustered_coords(rng, int(rng.integers(1, 700)), 3)
        b = clustered_coords(rng, int(rng.integers(1, 700)), 3)
        tasks.append((i % 3, a, b, False))
        tasks.append((i % 3, a, a, True))
    tasks.append((0, np.zeros((0, 3), np.int32), a, False))
    return tasks


def test_executor_parity_dense_block_numpy():
    rng = np.random.default_rng(1)
    tasks = make_tasks(rng)
    eps = 40
    dense = PallasJoinExecutor(prune="dense")
    block = PallasJoinExecutor(prune="block")
    ref = NumpyJoinExecutor(count_similar_pairs_np)
    cd = dense.count_pairs(tasks, eps)
    cb = block.count_pairs(tasks, eps)
    cn = ref.count_pairs(tasks, eps)
    assert cd == cb == cn
    assert sum(cd) > 0
    assert dense.last_stats["block_pairs_evaluated"] == \
        dense.last_stats["block_pairs_total"]
    assert block.last_stats["block_pairs_total"] == \
        dense.last_stats["block_pairs_total"]
    assert block.last_stats["block_pairs_evaluated"] <= \
        block.last_stats["block_pairs_total"]
    assert ref.last_stats is None


def test_no_retrace_across_repeated_same_shape_queries():
    """Repeated same-shape dispatches must hit the memoized jitted
    callables without re-tracing (ops.TRACE_COUNTS bumps at trace time
    only) — the recompile guard of the batched executor."""
    rng = np.random.default_rng(2)
    tasks = make_tasks(rng, k=4)
    for prune_mode in ("dense", "block", "bitmap"):
        ex = PallasJoinExecutor(prune=prune_mode)
        first = ex.count_pairs(tasks, 25)       # traces once per bucket
        before = dict(ops.TRACE_COUNTS)
        for _ in range(3):
            assert ex.count_pairs(tasks, 25) == first
        assert dict(ops.TRACE_COUNTS) == before, prune_mode
        assert len(ex._fn_cache) > 0


def test_make_join_executor_prune_validation():
    with pytest.raises(ValueError, match="prune"):
        make_join_executor("numpy", count_similar_pairs_np, prune="block")
    with pytest.raises(ValueError, match="prune"):
        make_join_executor("numpy", count_similar_pairs_np, prune="bitmap")
    with pytest.raises(ValueError, match="unknown prune mode"):
        PallasJoinExecutor(prune="sparse")


# ------------------------------------------------------ backend parity

@pytest.fixture(scope="module")
def dataset():
    from repro.arrayio.catalog import build_catalog
    from repro.arrayio.generator import make_ptf_files
    files = make_ptf_files(n_files=10, cells_per_file_mean=900, seed=21)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="bprune_"),
                                  "fits", n_nodes=4)
    return catalog, data


def run_cluster(dataset, backend, prune, queries):
    from repro.arrayio.catalog import FileReader
    from repro.core.cluster import RawArrayCluster
    catalog, data = dataset
    cluster = RawArrayCluster(catalog, FileReader(catalog, data), 4,
                              8_000, policy="cost", min_cells=64,
                              backend=backend, join_backend="pallas",
                              prune=prune)
    return cluster.run_workload(queries)


def test_backend_parity_pruned(dataset):
    """Identical per-query match counts under prune=dense/block on the
    simulated backend and prune=block on the device mesh, with the
    block-pair counters populated on every ExecutedQuery."""
    from repro.core.workload import ptf1_workload, ptf2_workload
    catalog, _ = dataset
    queries = (ptf1_workload(catalog.domain, n_queries=4, eps=300, seed=7)
               + ptf2_workload(catalog.domain, n_queries=4, eps=300))
    runs = {
        ("simulated", "dense"): run_cluster(dataset, "simulated", "dense",
                                            queries),
        ("simulated", "block"): run_cluster(dataset, "simulated", "block",
                                            queries),
        ("jax_mesh", "block"): run_cluster(dataset, "jax_mesh", "block",
                                           queries),
    }
    base = [e.matches for e in runs[("simulated", "dense")]]
    assert sum(m or 0 for m in base) > 0
    for key, executed in runs.items():
        assert [e.matches for e in executed] == base, key
        joined = [e for e in executed if e.report.join_plan is not None]
        assert all(e.block_pairs_total is not None for e in joined), key
        assert all((e.block_pairs_evaluated or 0)
                   <= (e.block_pairs_total or 0) for e in joined), key
    blocked = runs[("simulated", "block")]
    dense = runs[("simulated", "dense")]
    assert (sum(e.block_pairs_total or 0 for e in blocked)
            == sum(e.block_pairs_total or 0 for e in dense))


def test_workload_summary_block_counters(dataset):
    from repro.backend import workload_summary
    from repro.core.workload import ptf2_workload
    catalog, _ = dataset
    queries = ptf2_workload(catalog.domain, n_queries=4, eps=300)
    summ = workload_summary(run_cluster(dataset, "simulated", "block",
                                        queries))
    assert "block_pairs_total" in summ
    assert summ["block_pairs_evaluated"] <= summ["block_pairs_total"]
    # The numpy executor path reports no block counters at all.
    from repro.arrayio.catalog import FileReader
    from repro.core.cluster import RawArrayCluster
    catalog, data = dataset
    np_run = RawArrayCluster(catalog, FileReader(catalog, data), 4, 8_000,
                             policy="cost", min_cells=64,
                             join_backend="numpy").run_workload(queries)
    assert "block_pairs_total" not in workload_summary(np_run)
