"""The unified telemetry layer (ISSUE 8): span nesting/parenting
invariants and Chrome trace export, the typed metrics registry's
emission-group semantics, the event channel that replaced the ad-hoc
pending-exec dict (including the leftover-events-after-the-last-query
fix), injectable clocks, and the two equivalence contracts — off-mode
summaries bit-identical to seed behavior, and the live registry's
``as_summary()`` equal to ``workload_summary`` on a mixed workload for
both backends."""
import json

import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_ptf_files
from repro.backend.base import (register_summary_counters, record_executed,
                                workload_summary)
from repro.core.cluster import RawArrayCluster
from repro.core.result_cache import ResultCache
from repro.core.workload import zipf_workload
from repro.obs import (Clock, EventChannel, ManualClock, MetricsRegistry,
                       MONOTONIC, NULL_REGISTRY, NULL_TELEMETRY, NULL_TRACER,
                       Telemetry, Tracer, as_clock, make_telemetry)

N_NODES = 4


@pytest.fixture(scope="module")
def ptf(tmp_path_factory):
    root = tmp_path_factory.mktemp("ptf_tel")
    files = make_ptf_files(n_files=8, cells_per_file_mean=700, seed=11)
    catalog, data = build_catalog(files, str(root), "fits", n_nodes=N_NODES)
    return catalog, data


def make_cluster(ptf, budget=400_000, **kw):
    catalog, data = ptf
    return RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                           budget, policy="cost", min_cells=64, **kw)


def skewed(catalog, n_queries=18, seed=3):
    return zipf_workload(catalog.domain, n_queries=n_queries, n_templates=3,
                         s=1.5, eps=1, field_frac=0.25, seed=seed)


# ----------------------------------------------------------------- clock

def test_as_clock_adapters():
    assert as_clock(None) is MONOTONIC
    mc = ManualClock(start=5.0)
    assert as_clock(mc) is mc
    ticks = [1.0]
    wrapped = as_clock(lambda: ticks[0])
    assert isinstance(wrapped, Clock) and wrapped.now() == 1.0
    ticks[0] = 2.5
    assert wrapped.now() == 2.5
    with pytest.raises(TypeError):
        as_clock(42)


def test_manual_clock_advance_and_auto_step():
    mc = ManualClock(start=10.0, auto_step=0.5)
    assert mc.now() == 10.0
    assert mc.now() == 10.5
    mc.advance(4.0)
    assert mc.now() == 15.0
    with pytest.raises(ValueError):
        mc.advance(-1.0)
    frozen = ManualClock(start=3.0)
    assert frozen.now() == frozen.now() == 3.0


def test_monotonic_clock_advances():
    a = MONOTONIC.now()
    b = MONOTONIC.now()
    assert b >= a


# ---------------------------------------------------------------- tracer

def test_span_parenting_follows_open_stack():
    tr = Tracer(clock=ManualClock(auto_step=1.0))
    with tr.span("workload") as root:
        with tr.span("batch") as b:
            with tr.span("plan.scan") as s:
                pass
        with tr.span("dispatch") as d:
            pass
    assert root.parent_id is None
    assert b.parent_id == root.span_id
    assert s.parent_id == b.span_id
    assert d.parent_id == root.span_id          # sibling, not child of b
    assert all(sp.end is not None for sp in tr.spans)
    # parent intervals contain child intervals under the manual clock
    assert root.start <= b.start and b.end <= root.end
    assert b.start <= s.start and s.end <= b.end


def test_explicit_parent_override():
    tr = Tracer(clock=ManualClock(auto_step=1.0))
    root = tr.begin("workload")
    detached = tr.begin("recover", parent=root)
    inner = tr.begin("plan.scan")               # implicit: innermost open
    assert detached.parent_id == root.span_id
    assert inner.parent_id == detached.span_id
    tr.end(root)                                # closes descendants too
    assert inner.end is not None and detached.end is not None
    # innermost-first: children end no later than their parents
    assert inner.end <= detached.end <= root.end


def test_begin_end_pair_and_out_of_order_close():
    tr = Tracer(clock=ManualClock(auto_step=1.0))
    a = tr.begin("a")
    b = tr.begin("b")
    tr.end(a)                                   # b still open: closed first
    assert b.end is not None and b.end <= a.end
    c = tr.begin("c")
    tr.end(c)
    tr.end(c)                                   # double-end: no crash
    assert c.duration_s == 1.0
    assert a.duration_s > 0 and b.duration_s > 0


def test_open_span_duration_is_zero():
    tr = Tracer(clock=ManualClock(auto_step=1.0))
    s = tr.begin("open")
    assert s.duration_s == 0.0
    tr.end(s)
    assert s.duration_s == 1.0


def test_chrome_trace_shape(tmp_path):
    tr = Tracer(clock=ManualClock(start=100.0, auto_step=1.0), pid=7)
    with tr.span("workload", queries=2):
        with tr.span("query", cat="query"):
            pass
    doc = tr.to_chrome_trace()
    events = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    meta, root_ev, child_ev = events
    assert meta["ph"] == "M" and meta["name"] == "process_name"
    assert root_ev["ph"] == "X" and root_ev["name"] == "workload"
    assert root_ev["ts"] == 0.0                 # normalized to earliest
    assert root_ev["pid"] == 7
    assert root_ev["args"]["queries"] == 2
    assert child_ev["cat"] == "query"
    assert child_ev["args"]["parent_id"] == root_ev["args"]["span_id"]
    assert child_ev["ts"] > 0 and child_ev["dur"] > 0
    path = tr.export(str(tmp_path / "t.trace.json"))
    assert json.load(open(path)) == doc


def test_null_tracer_is_inert():
    assert NULL_TRACER.begin("x") is None
    NULL_TRACER.end(None)
    with NULL_TRACER.span("x") as s:
        assert s is None
    assert NULL_TRACER.spans == []
    assert NULL_TRACER.to_chrome_trace() == {"traceEvents": [],
                                             "displayTimeUnit": "ms"}


# -------------------------------------------------------------- registry

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    c.inc()
    c.inc(2.5)
    assert reg.counter("hits") is c and c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("util", node=1)
    g.set(0.75)
    assert reg.gauge("util", node=1).value == 0.75
    assert reg.gauge("util", node=2) is not g   # distinct label series
    h = reg.histogram("churn", bounds=(1, 4, 16))
    for v in (0, 1, 2, 100):
        h.observe(v)
    assert h.count == 4 and h.sum == 103
    assert sum(h.bucket_counts) == h.count
    assert h.bucket_counts[-1] == 1             # the overflow bucket


def test_registry_conflicts_rejected():
    reg = MetricsRegistry()
    reg.counter("x", group="a")
    with pytest.raises(ValueError):
        reg.counter("x", group="b")             # group fixed at creation
    with pytest.raises(ValueError):
        reg.gauge("x")                          # cross-kind collision
    reg.histogram("h", bounds=(1, 2))
    with pytest.raises(ValueError):
        reg.histogram("h", bounds=(1, 3))       # bounds must agree
    with pytest.raises(ValueError):
        reg.histogram("bad", bounds=(2, 1))     # not ascending


def test_as_summary_groups_and_order():
    reg = MetricsRegistry()
    reg.counter("always").inc(1)
    reg.counter("mqo_tasks_total", group="mqo").inc(5)
    reg.counter("replica_hits", group="replica").inc(2)
    assert reg.as_summary() == {"always": 1.0}  # no group marked yet
    reg.mark_group("mqo")
    summ = reg.as_summary()
    assert summ == {"always": 1.0, "mqo_tasks_total": 5.0}
    assert list(summ) == ["always", "mqo_tasks_total"]  # registration order
    assert all(isinstance(v, float) for v in summ.values())


def test_null_registry_is_inert():
    c = NULL_REGISTRY.counter("anything", group="g")
    c.inc(5)
    NULL_REGISTRY.gauge("g", node=0).set(1)
    NULL_REGISTRY.histogram("h", bounds=(1,)).observe(3)
    NULL_REGISTRY.mark_group("g")
    assert NULL_REGISTRY.as_summary() == {}


# --------------------------------------------------------- event channel

def test_event_channel_accumulates_and_mirrors():
    reg = MetricsRegistry()
    ch = EventChannel(reg)
    assert ch.empty()
    ch.post("failover_readmits", 3)
    ch.post("failover_readmits", 2)
    ch.post("replicas_dropped")
    assert ch.peek() == {"failover_readmits": 5, "replicas_dropped": 1}
    assert not ch.empty()
    assert reg.counter("events.failover_readmits").value == 5
    assert ch.drain() == {"failover_readmits": 5, "replicas_dropped": 1}
    assert ch.empty() and ch.drain() == {}
    # mirrors live in the never-marked "events" group: not in summaries
    assert "events.failover_readmits" not in reg.as_summary()


def test_telemetry_modes_and_make_telemetry():
    on = Telemetry("on", clock=ManualClock())
    assert on.enabled and isinstance(on.tracer, Tracer)
    off = make_telemetry("off")
    assert off is NULL_TELEMETRY is make_telemetry(None)
    assert not off.enabled
    assert off.tracer is NULL_TRACER and off.registry is NULL_REGISTRY
    assert make_telemetry(on) is on
    with pytest.raises(ValueError):
        make_telemetry("loud")
    with pytest.raises(ValueError):
        Telemetry("loud")


def test_off_mode_trace_export_is_wellformed(ptf, tmp_path):
    cl = make_cluster(ptf)                      # telemetry="off" default
    cl.run_workload(skewed(cl.catalog, n_queries=4))
    path = cl.export_trace(str(tmp_path / "off.trace.json"))
    assert json.load(open(path)) == {"traceEvents": [],
                                     "displayTimeUnit": "ms"}


# -------------------------------------------- equivalence: off == legacy

def test_off_mode_summary_bit_identical_to_on_mode(ptf):
    """With a frozen injected clock the numpy/simulated pipeline is fully
    deterministic, so telemetry on vs off must produce *bit-identical*
    summaries — instrumentation may not perturb a single counter or
    timing."""
    def run(mode):
        tel = Telemetry(mode, clock=ManualClock())
        cl = make_cluster(ptf, reuse="on", mqo="on", result_cache="on",
                          replication="hot", join_backend="numpy",
                          telemetry=tel)
        ex = cl.run_workload(skewed(cl.catalog), batch_size=6)
        return cl.summary(ex)

    s_off, s_on = run("off"), run("on")
    assert s_off == s_on
    assert list(s_off) == list(s_on)            # same key order too
    assert s_off["queries"] == 18.0


# ------------------------------------- equivalence: registry == summary

@pytest.mark.parametrize("backend", ["simulated", "jax_mesh"])
def test_live_registry_matches_workload_summary(ptf, backend):
    if backend == "jax_mesh":
        pytest.importorskip("jax")
    cl = make_cluster(ptf, reuse="on", mqo="on", result_cache="on",
                      replication="hot", join_backend="pallas",
                      backend=backend, telemetry="on")
    ex = cl.run_workload(skewed(cl.catalog, n_queries=24), batch_size=6)
    legacy = workload_summary(ex)
    live = cl.telemetry.registry.as_summary()
    assert live == legacy
    assert list(live) == list(legacy)
    # the mixed workload must actually engage the optional tiers
    assert legacy["mqo_tasks_total"] > 0
    assert legacy["queries"] == 24.0


def test_record_executed_incremental_equals_batch_fold(ptf):
    cl = make_cluster(ptf, reuse="on", join_backend="pallas")
    ex = cl.run_workload(skewed(cl.catalog, n_queries=8))
    reg = MetricsRegistry()
    register_summary_counters(reg)
    for e in ex:
        record_executed(reg, e)
    assert reg.as_summary() == workload_summary(ex)


# --------------------------- satellite 1: leftover events after last query

def test_leftover_events_surface_in_summary(ptf):
    """A ``fail_node`` *after* the last query used to leave its recovery
    counters stranded in the pending channel forever; they must now be
    drained into the summary, leaving the channel empty."""
    cl = make_cluster(ptf, replication="hot", telemetry="on")
    ex = cl.run_workload(skewed(cl.catalog), batch_size=6)
    baseline = workload_summary(ex)
    cl.fail_node(0)
    pending = cl.coordinator.events.peek()
    assert pending and "failover_readmits" in pending
    summ = cl.summary(ex)                       # drains the leftovers
    assert cl.coordinator.events.empty()
    assert summ["failover_readmits"] == \
        baseline.get("failover_readmits", 0) + pending["failover_readmits"]
    for k in ("recovery_bytes_from_replica", "recovery_bytes_from_raw",
              "recovery_s"):
        assert k in summ
    # the drain is one-shot: a second summary is back to the baseline
    assert cl.summary(ex) == baseline


def test_events_between_queries_still_drain_into_executed(ptf):
    """The pre-existing path: events posted mid-workload land on the next
    executed query, not in the leftover drain."""
    cl = make_cluster(ptf, replication="hot", telemetry="on")
    queries = skewed(cl.catalog)
    cl.run_workload(queries[:9], batch_size=3)
    cl.fail_node(0)
    assert not cl.coordinator.events.empty()
    more = cl.run_workload(queries[9:], batch_size=3)
    assert cl.coordinator.events.empty()        # drained by execution
    assert sum(e.failover_readmits or 0 for e in more) > 0


# ------------------------------------------------- clock injection sites

def test_result_cache_accepts_clock_objects_and_callables():
    from repro.core.geometry import Box
    now = [0.0]
    rc1 = ResultCache(ttl_s=10.0, clock=lambda: now[0])      # back-compat
    mc = ManualClock()
    rc2 = ResultCache(ttl_s=10.0, clock=mc)                  # Clock object
    key = ResultCache.key_of(Box((0,), (1,)), 1)
    rc1.store(key, 5)
    rc2.store(key, 5)
    now[0] = 11.0
    mc.advance(11.0)
    assert rc1.lookup(key) is None and rc1.expired_drops == 1
    assert rc2.lookup(key) is None and rc2.expired_drops == 1


def test_cluster_coordinator_shares_telemetry_clock(ptf):
    mc = ManualClock(auto_step=0.001)
    cl = make_cluster(ptf, telemetry=Telemetry("on", clock=mc))
    assert cl.coordinator.clock.now() == pytest.approx(mc.now() - 0.001)
    assert cl.telemetry.tracer.clock is mc
