"""Training substrate: optimizer math, microbatch-accumulation equivalence,
checkpoint round-trip + atomicity, fault-tolerant resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, reduced
from repro.models.model import init_params
from repro.train.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                                    restore_checkpoint, save_checkpoint)
from repro.train.fault_tolerance import (ClusterMonitor, TrainingSupervisor,
                                         plan_elastic_remesh)
from repro.train.optimizer import (OptimizerConfig, adamw_init, adamw_update,
                                   global_norm, lr_schedule)
from repro.train.train_step import make_train_step


def small_cfg():
    return reduced(get("qwen1.5-0.5b"), d_model=32, n_periods=1, vocab=64)


def make_batch(cfg, key, b=4, s=8):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                          total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-3)


def test_adamw_moves_params_and_clips():
    cfg = OptimizerConfig(clip_norm=1e-6)    # absurd clip -> tiny update
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    state = adamw_init(params, cfg)
    new_params, state, metrics = adamw_update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)
    assert float(jnp.abs(new_params["w"] - params["w"]).max()) < 1e-3


def test_train_loss_decreases():
    cfg = small_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt_cfg = OptimizerConfig(peak_lr=5e-3, warmup_steps=2, total_steps=60)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = make_batch(cfg, key)     # overfit one batch
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7


def test_microbatch_accumulation_matches_full_batch():
    cfg = small_cfg()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opt_cfg = OptimizerConfig()
    batch = make_batch(cfg, key, b=8)
    p1, _, m1 = jax.jit(make_train_step(cfg, opt_cfg, n_microbatches=1))(
        params, adamw_init(params, opt_cfg), batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt_cfg, n_microbatches=4))(
        params, adamw_init(params, opt_cfg), batch)
    # Same data -> same loss; grads averaged -> near-identical update.
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                   b.astype(jnp.float32)).max()), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 0.05


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32)}}
    path = save_checkpoint(str(tmp_path), 7, tree, extra={"k": 1})
    assert latest_checkpoint(str(tmp_path)) == path
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step, extra = restore_checkpoint(path, like)
    assert step == 7 and extra == {"k": 1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_no_tmp(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for s in range(5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    entries = sorted(os.listdir(tmp_path))
    assert entries == ["step_00000003", "step_00000004"]
    assert not any(e.endswith(".tmp") for e in entries)


def test_async_checkpointer(tmp_path):
    ckpt = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.full((8, 8), 3.0)}
    ckpt.save(1, tree)
    ckpt.save(2, tree)       # waits for the first
    ckpt.wait()
    assert ckpt.saved_steps == [1, 2]
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000002")


def test_monitor_detects_death_and_stragglers():
    t = [0.0]
    mon = ClusterMonitor(4, heartbeat_timeout=5.0, straggler_factor=1.5,
                         clock=lambda: t[0])
    for i in range(4):
        mon.heartbeat(i, step_time_s=1.0 + (0.1 if i else 0.0))
    t[0] = 3.0
    for i in range(3):       # node 3 goes silent
        mon.heartbeat(i, step_time_s=1.0)
    mon.heartbeat(2, step_time_s=5.0)   # node 2 straggles
    mon.heartbeat(2, step_time_s=5.0)
    t[0] = 7.0
    assert mon.dead_nodes() == [3]
    rep = mon.stragglers()
    assert 2 in rep.stragglers


def test_elastic_remesh_reuses_placement():
    shard_sizes = {i: 100 for i in range(8)}
    shard_layer = {i: i // 2 for i in range(8)}     # pairs per layer
    current = {0: 0, 1: 0, 2: 1, 3: 1, 4: 2, 5: 2, 6: 3, 7: 3}
    plan = plan_elastic_remesh(
        n_hosts_alive=3, model_parallel=1, shard_sizes=shard_sizes,
        shard_layer=shard_layer, lost_host_shards=[6, 7],
        host_budget_bytes=400, current_host=current)
    assert plan.new_dp == 3
    assert set(plan.shard_moves) >= {6, 7}
    # Layer-3 shards (6, 7) should land on the same host (co-locality).
    assert plan.shard_moves[6] == plan.shard_moves[7]


def test_supervisor_restart_is_deterministic(tmp_path):
    """Training with injected failures == uninterrupted training."""
    cfg = small_cfg()
    key = jax.random.PRNGKey(2)
    opt_cfg = OptimizerConfig(peak_lr=1e-3, total_steps=30)
    step_jit = jax.jit(make_train_step(cfg, opt_cfg))
    batches = [make_batch(cfg, jax.random.PRNGKey(100 + i)) for i in
               range(12)]

    def fresh_state():
        params = init_params(cfg, key)
        return {"step": 0, "params": params,
                "opt": adamw_init(params, opt_cfg)}

    def run(failures, ckdir):
        ck = AsyncCheckpointer(ckdir, keep=2)
        st0 = fresh_state()
        save_checkpoint(ckdir, 0, {"params": st0["params"],
                                   "opt": st0["opt"]})

        def step_fn(state):
            p, o, _ = step_jit(state["params"], state["opt"],
                               batches[state["step"]])
            return {"step": state["step"] + 1, "params": p, "opt": o,
                    "tree": {"params": p, "opt": o}}

        def restore():
            latest = latest_checkpoint(ckdir)
            like = {"params": st0["params"], "opt": st0["opt"]}
            tree, step, _ = restore_checkpoint(latest, like)
            return {"step": step, "params": tree["params"],
                    "opt": tree["opt"],
                    "tree": tree}

        sup = TrainingSupervisor(ck, restore, ckpt_every=4)
        state = {"step": 0, "params": st0["params"], "opt": st0["opt"],
                 "tree": {"params": st0["params"], "opt": st0["opt"]}}
        return sup.run(state, step_fn, total_steps=12,
                       failure_at=set(failures))

    clean = run([], str(tmp_path / "a"))
    faulty = run([5, 9], str(tmp_path / "b"))
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
