#!/usr/bin/env python
"""Standalone cross-layer invariant audit (ISSUE 10 tentpole tooling).

Builds a seeded clustered-GEO workload, runs it — optionally under a
seeded fault storm and/or with a mid-run node crash — then runs a final
:class:`~repro.faults.audit.InvariantAuditor` pass over the terminal
cache state and prints its report. Exits nonzero if ANY invariant was
violated at any point (per-round audits are armed throughout the run,
not just at the end, so a transient divergence that later self-heals
still fails).

The audited invariants: residency ⊇ device buffers ⊇ artifacts,
coverage-index extents == resident chunk extents, replica-location
well-formedness + byte accounting, and result-cache version
monotonicity (see ``repro/faults/audit.py``).

Usage:

    PYTHONPATH=src python tools/audit_state.py [--backend jax_mesh]
                                               [--fault-rate 0.1]
                                               [--seed 0] [--fail-node]
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def main(argv=None) -> int:
    """Run the audited GEO workload; returns an exit code."""
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_geo_files
    from repro.core.cluster import RawArrayCluster, workload_summary
    from repro.core.geometry import Box
    from repro.core.workload import geo_workload
    from repro.faults import FaultInjector

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="simulated",
                    choices=("simulated", "jax_mesh"))
    ap.add_argument("--fault-rate", type=float, default=0.10,
                    help="per-crossing storm rate (0 disables injection "
                         "but keeps the auditor armed)")
    ap.add_argument("--seed", type=int, default=0,
                    help="storm schedule seed")
    ap.add_argument("--fail-node", action="store_true",
                    help="also crash the fullest node mid-workload and "
                         "audit the recovered state")
    args = ap.parse_args(argv)

    files = make_geo_files(n_files=12, n_seeds=120, clones_per_seed=12,
                           domain=Box((1, 1), (4000, 2000)), seed=11)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="audit_state_"),
                                  "fits", n_nodes=4)
    reader = FileReader(catalog, data)
    queries = geo_workload(catalog.domain, eps=20, seed=9)

    faults = (FaultInjector.storm(args.fault_rate, seed=args.seed)
              if args.fault_rate > 0 else "off")
    cluster = RawArrayCluster(catalog, reader, 4, 300_000, policy="cost",
                              min_cells=64, backend=args.backend,
                              replication="hot", replica_k=2,
                              replication_threshold=2.0,
                              faults=faults, audit="on")
    half = len(queries) // 2
    executed = cluster.run_workload(queries[:half], batch_size=2)
    if args.fail_node:
        chunk_bytes, _ = cluster.coordinator.chunks.size_tables()
        by_node = cluster.coordinator.cache.bytes_by_node(chunk_bytes)
        victim = max(by_node, key=lambda n: (by_node[n], -n))
        cluster.fail_node(victim)
        print(f"crashed node {victim} mid-workload")
    executed += cluster.run_workload(queries[half:], batch_size=2)

    auditor = cluster.coordinator.auditor
    final = auditor.audit()          # one terminal pass over end state
    summ = workload_summary(executed)
    matches = sum(e.matches or 0 for e in executed)
    print(f"queries={len(executed)} matches={matches} "
          f"injected={summ.get('faults_injected', 0)} "
          f"retries={summ.get('retries', 0)} "
          f"degraded={summ.get('degraded_queries', 0)}")
    print(auditor.report())
    if auditor.violations_total > 0:
        print(f"FAIL: {auditor.violations_total} invariant violation(s) "
              f"({len(final)} in the terminal pass)", file=sys.stderr)
        return 1
    print("OK: zero invariant violations across "
          f"{auditor.audits_run} audit passes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
