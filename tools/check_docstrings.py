#!/usr/bin/env python
"""Docstring-coverage gate (an ``interrogate`` equivalent on the stdlib).

Counts docstrings on modules, public classes, and public
functions/methods (names not starting with ``_``) across a directory
tree, reports per-file coverage, and exits non-zero when aggregate
coverage falls below ``--fail-under``. Used by CI and by
``tests/test_doc_coverage.py`` to keep ``src/repro/core`` documented:

    python tools/check_docstrings.py src/repro/core --fail-under 90
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import Iterator, List, Tuple


def _iter_nodes(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """Yield (qualified name, node) for the module and every public
    class/function defined at module or class level. Nested (closure)
    functions are implementation detail and are not counted."""
    yield "<module>", tree

    def walk(body, prefix):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                if node.name.startswith("_"):
                    continue
                name = f"{prefix}{node.name}"
                yield name, node
                if isinstance(node, ast.ClassDef):
                    yield from walk(node.body, f"{name}.")

    yield from walk(tree.body, "")


def file_report(path: str) -> Tuple[int, int, List[str]]:
    """(documented, total, missing-names) for one source file."""
    with open(path, "r", encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=path)
    documented, total, missing = 0, 0, []
    for name, node in _iter_nodes(tree):
        total += 1
        if ast.get_docstring(node):
            documented += 1
        else:
            missing.append(name)
    return documented, total, missing


def main(argv=None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+",
                    help="files or directories to scan for .py sources")
    ap.add_argument("--fail-under", type=float, default=90.0,
                    help="minimum aggregate coverage percentage")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="only print the summary line")
    args = ap.parse_args(argv)

    sources: List[str] = []
    for p in args.paths:
        if os.path.isfile(p):
            sources.append(p)
        else:
            for root, _, names in os.walk(p):
                sources.extend(os.path.join(root, n) for n in sorted(names)
                               if n.endswith(".py"))
    documented = total = 0
    for src in sorted(sources):
        d, t, missing = file_report(src)
        documented += d
        total += t
        if not args.quiet and missing:
            for name in missing:
                print(f"MISSING {src}: {name}")
    pct = 100.0 * documented / total if total else 100.0
    status = "PASSED" if pct >= args.fail_under else "FAILED"
    print(f"doc coverage: {documented}/{total} = {pct:.1f}% "
          f"(required {args.fail_under:.1f}%) {status}")
    return 0 if pct >= args.fail_under else 1


if __name__ == "__main__":
    sys.exit(main())
