#!/usr/bin/env python
"""CI smoke gate for the join-artifact cache (ISSUE 5 satellite).

Runs a repeat-query clustered workload through the default
(``prune="auto"``, pallas) cluster and fails unless the warm pass

  * reports ``artifact_hits > 0`` — catches a silent cache bypass where
    the counters are wired but the executors stopped consulting the
    cache (every query would quietly repay the host-prep cost);
  * returns per-query match counts identical to the cold pass — catches
    a stale-artifact path where a hit serves wrong derived data.

Usage (the CI tier-1 job runs exactly this):

    PYTHONPATH=src python tools/smoke_artifact_counters.py
"""
from __future__ import annotations

import sys
import tempfile


def main() -> int:
    """Run the smoke workload; returns a process exit code."""
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_geo_files
    from repro.core.cluster import RawArrayCluster, workload_summary
    from repro.core.workload import geo_workload

    files = make_geo_files(n_files=3, n_seeds=120, clones_per_seed=20,
                           seed=5)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="smoke_art_"),
                                  "csv", n_nodes=4)
    # Budget covers the dataset: repeats must be answered warm.
    budget = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    cluster = RawArrayCluster(catalog, FileReader(catalog, data), 4,
                              budget // 4, policy="cost", min_cells=512,
                              join_backend="pallas")
    queries = geo_workload(catalog.domain, eps=300, range_frac=0.4)
    cold = cluster.run_workload(queries)
    warm = cluster.run_workload(queries)
    cold_m = [e.matches for e in cold]
    warm_m = [e.matches for e in warm]
    summ = workload_summary(warm)
    print(f"cold matches: {cold_m}")
    print(f"warm matches: {warm_m}")
    print(f"warm artifact_hits={summ.get('artifact_hits')} "
          f"artifact_misses={summ.get('artifact_misses')} "
          f"prep_s={summ.get('prep_s', 0.0):.4f} "
          f"dispatch_s={summ.get('dispatch_s', 0.0):.4f}")
    if summ.get("artifact_hits", 0) <= 0:
        print("FAIL: warm pass reported no artifact hits — the join-"
              "artifact cache is being bypassed", file=sys.stderr)
        return 1
    if warm_m != cold_m or sum(m or 0 for m in cold_m) <= 0:
        print("FAIL: warm match counts differ from cold (stale artifact "
              "served?)", file=sys.stderr)
        return 1
    print("OK: artifact cache hit on the warm pass with bit-identical "
          "match counts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
