#!/usr/bin/env python
"""CI smoke gate for the cell-exact bitmap prune stage (ISSUE 9).

Runs a clustered GEO workload under ``prune="bitmap"`` and fails unless

  * ``block_pairs_bitmap_killed > 0`` — the hierarchical-bitmap
    intersection must actually kill bbox-surviving block pairs on a
    clustered workload (catches a refinement stage that silently
    degrades to a pass-through);
  * per-query match counts are bit-identical to ``prune="dense"`` —
    the superset-of-matches invariant end-to-end (a kill that drops a
    real match is a correctness bug, not a perf regression);
  * the bitmap counters stay OUT of the ``prune="block"`` summary —
    the conditional emission group must keep seed summaries unchanged.

Usage (both CI tier-1 jobs run this; the mesh job adds the flag):

    PYTHONPATH=src python tools/smoke_bitmap_prune.py [--backend jax_mesh]
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def main() -> int:
    """Run the smoke workload; returns a process exit code."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="simulated",
                    choices=("simulated", "jax_mesh"))
    args = ap.parse_args()

    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_geo_files
    from repro.core.cluster import RawArrayCluster, workload_summary
    from repro.core.workload import geo_workload

    files = make_geo_files(n_files=3, n_seeds=150, clones_per_seed=25,
                           seed=13)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="smoke_bm_"),
                                  "csv", n_nodes=4)
    budget = sum(f.n_cells * f.cell_bytes for f in catalog.files)

    def run(prune):
        cluster = RawArrayCluster(catalog, FileReader(catalog, data), 4,
                                  budget // 8 // 4, policy="cost",
                                  min_cells=2048, join_backend="pallas",
                                  backend=args.backend, prune=prune)
        executed = cluster.run_workload(
            geo_workload(catalog.domain, eps=400, range_frac=0.45))
        return [e.matches for e in executed], workload_summary(executed)

    dense_m, _ = run("dense")
    block_m, block_s = run("block")
    bitmap_m, bitmap_s = run("bitmap")
    killed = bitmap_s.get("block_pairs_bitmap_killed", 0)
    print(f"dense matches:  {dense_m}")
    print(f"bitmap matches: {bitmap_m}")
    print(f"bitmap block_pairs_evaluated="
          f"{bitmap_s.get('block_pairs_evaluated'):.0f}/"
          f"{bitmap_s.get('block_pairs_total'):.0f} "
          f"(block mode: {block_s.get('block_pairs_evaluated'):.0f}) "
          f"bitmap_killed={killed:.0f} "
          f"bitmap_build_s={bitmap_s.get('bitmap_build_s', 0.0):.4f}")
    if bitmap_m != dense_m or sum(m or 0 for m in dense_m) <= 0:
        print("FAIL: bitmap-pruned match counts differ from dense — the "
              "cell-exact stage killed a pair containing a real match",
              file=sys.stderr)
        return 1
    if killed <= 0:
        print("FAIL: block_pairs_bitmap_killed == 0 on a clustered "
              "workload — the bitmap stage is not engaging",
              file=sys.stderr)
        return 1
    if (bitmap_s.get("block_pairs_evaluated", 0)
            > block_s.get("block_pairs_evaluated", 0)):
        print("FAIL: bitmap mode evaluated more pairs than block mode — "
              "refinement must only shrink pair lists", file=sys.stderr)
        return 1
    if "block_pairs_bitmap_killed" in block_s:
        print("FAIL: bitmap counters leaked into a prune=\"block\" "
              "summary — the emission group must stay gated",
              file=sys.stderr)
        return 1
    print(f"OK: bitmap stage killed {killed:.0f} bbox-surviving block "
          f"pairs with bit-identical matches vs dense "
          f"({args.backend} backend)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
