#!/usr/bin/env python
"""CI smoke gate for the transient-fault pipeline (ISSUE 10 satellite).

Runs a seeded Zipf repeat workload twice through a replicated cluster —
once fault-free, once under a seeded fault storm (error + latency +
corruption faults at every fault point) — and fails unless

  * ``faults_injected > 0`` and ``retries > 0`` — catches a dead seam
    (fault points never armed) or a retrier that never engages;
  * at least one transfer re-routed to a surviving replica — catches a
    retry loop that hammers the same dead source instead of re-routing;
  * the ``InvariantAuditor`` reports ZERO violations — catches a
    listener-coupled cache tier diverging under the storm;
  * every query that completed (non-degraded) has a match count
    bit-identical to the fault-free reference — catches a retry/degrade
    path serving partial or corrupted results as complete;
  * the same seed reproduces the identical injection schedule — catches
    nondeterminism in the injector's per-site RNG streams.

Usage (both CI tier-1 jobs run exactly this; the mesh job passes
``--backend jax_mesh``):

    PYTHONPATH=src python tools/smoke_chaos.py [--backend jax_mesh]
"""
from __future__ import annotations

import argparse
import sys
import tempfile

#: Per-crossing fault rate of the storm (ship.transfer is boosted so the
#: replica re-route path demonstrably engages in a short workload).
STORM_RATE = 0.10
SHIP_RATE = 0.45
STORM_SEED = 1234


def build_storm():
    """The smoke's seeded fault storm: every point at :data:`STORM_RATE`
    with all three kinds, except ``ship.transfer`` which fires error and
    corruption faults at :data:`SHIP_RATE` so retries must re-route and
    the per-chunk checksums must catch bit-flipped payloads."""
    from repro.faults import FAULT_POINTS, FaultInjector, FaultSpec
    specs = [FaultSpec("ship.transfer", SHIP_RATE,
                       kinds=("error", "corrupt"))]
    specs += [FaultSpec(p, STORM_RATE, kinds=("error", "latency", "corrupt"))
              for p in FAULT_POINTS if p != "ship.transfer"]
    return FaultInjector(specs, seed=STORM_SEED)


def main(argv=None) -> int:
    """Run the chaos smoke workload; returns an exit code."""
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_ptf_files
    from repro.core.cluster import RawArrayCluster, workload_summary

    from repro.core.workload import zipf_workload

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="simulated",
                    choices=("simulated", "jax_mesh"))
    args = ap.parse_args(argv)

    files = make_ptf_files(n_files=12, cells_per_file_mean=700, seed=11)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="smoke_chaos_"),
                                  "fits", n_nodes=4)
    reader = FileReader(catalog, data)
    # field_frac=0.5 makes query boxes span files on several nodes, so
    # the join planner actually ships chunks — the storm needs live
    # ``ship.transfer`` crossings to demonstrate replica re-routing.
    queries = zipf_workload(catalog.domain, n_queries=24, n_templates=3,
                            s=1.5, eps=150, field_frac=0.5, seed=3)

    def run(faults):
        cluster = RawArrayCluster(
            catalog, reader, 4, 400_000, policy="cost", min_cells=64,
            backend=args.backend, replication="hot", replica_k=2,
            replication_threshold=2.0, faults=faults)
        executed = cluster.run_workload(queries, batch_size=3)
        return cluster, executed

    _, ref = run("off")
    ref_m = [e.matches for e in ref]
    if any(e.faults_injected is not None for e in ref):
        print("FAIL: faults='off' run carries fault counters — the "
              "seed-parity gate leaks", file=sys.stderr)
        return 1

    cluster, executed = run(build_storm())
    summ = workload_summary(executed)
    injected = summ.get("faults_injected", 0)
    retries = summ.get("retries", 0)
    reroutes = summ.get("transfer_reroutes", 0)
    violations = summ.get("audit_violations", 0)
    degraded = int(summ.get("degraded_queries", 0))
    print(f"storm: injected={injected} retries={retries} "
          f"reroutes={reroutes} raw_fallbacks={summ.get('raw_fallbacks')} "
          f"checksum_mismatch={summ.get('checksum_mismatch')} "
          f"degraded={degraded} audit_violations={violations}")
    if injected <= 0 or retries <= 0:
        print("FAIL: the storm injected nothing or nothing retried — "
              "the fault seam or retrier is dead", file=sys.stderr)
        return 1
    if reroutes < 1:
        print("FAIL: no transfer re-routed to a surviving replica",
              file=sys.stderr)
        return 1
    if violations != 0:
        print("FAIL: invariant auditor found violations:\n"
              + cluster.coordinator.auditor.report(), file=sys.stderr)
        return 1
    mismatched = [i for i, (e, m) in enumerate(zip(executed, ref_m))
                  if e.degraded is None and e.matches != m]
    if mismatched or sum(m or 0 for m in ref_m) <= 0:
        print(f"FAIL: completed queries {mismatched} differ from the "
              f"fault-free reference (partial/corrupt results served as "
              f"complete)", file=sys.stderr)
        return 1

    cluster2, executed2 = run(build_storm())
    if (cluster.coordinator.faults.schedule_log
            != cluster2.coordinator.faults.schedule_log):
        print("FAIL: same-seed storms produced different injection "
              "schedules", file=sys.stderr)
        return 1
    print(f"OK: storm injected+retried+re-routed, zero audit violations, "
          f"{len(executed) - degraded}/{len(executed)} completed queries "
          f"bit-identical, schedule reproducible")
    return 0


if __name__ == "__main__":
    sys.exit(main())
