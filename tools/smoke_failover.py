#!/usr/bin/env python
"""CI smoke gate for hot-chunk replication + simulated failure handling
(ISSUE 7 satellite).

Runs a seeded Zipf repeat workload through a replicated cluster, kills
the hottest node (most cached bytes) halfway, finishes the workload, and
fails unless

  * ``failover_readmits > 0`` — catches a dead recovery path (a crash
    that silently leaves the cache cold instead of re-admitting from
    surviving replicas / raw files);
  * at least one chunk held >1 replica before the kill — catches a
    replication round that silently never promotes;
  * total (and per-query) match counts are bit-identical to an unfailed
    single-copy reference run — catches a failover path serving stale
    or partial results.

Usage (both CI tier-1 jobs run exactly this; the mesh job passes
``--backend jax_mesh``):

    PYTHONPATH=src python tools/smoke_failover.py [--backend jax_mesh]
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def main(argv=None) -> int:
    """Run the fault-injection smoke workload; returns an exit code."""
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_ptf_files
    from repro.core.cluster import RawArrayCluster, workload_summary
    from repro.core.workload import zipf_workload

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="simulated",
                    choices=("simulated", "jax_mesh"))
    args = ap.parse_args(argv)

    files = make_ptf_files(n_files=8, cells_per_file_mean=700, seed=11)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="smoke_failover_"),
                                  "fits", n_nodes=4)
    reader = FileReader(catalog, data)
    queries = zipf_workload(catalog.domain, n_queries=18, n_templates=3,
                            s=1.5, eps=150, field_frac=0.25, seed=3)

    def build(replication: str) -> RawArrayCluster:
        return RawArrayCluster(catalog, reader, 4, 400_000, policy="cost",
                               min_cells=64, backend=args.backend,
                               replication=replication, replica_k=2,
                               replication_threshold=2.0)

    ref_m = [e.matches
             for e in build("off").run_workload(queries, batch_size=3)]

    cluster = build("hot")
    half = len(queries) // 2
    executed = cluster.run_workload(queries[:half], batch_size=3)
    cache = cluster.coordinator.cache
    replicated = sum(len(reps) > 1 for _, reps in cache.location_items())
    chunk_bytes, _ = cluster.coordinator.chunks.size_tables()
    by_node = cache.bytes_by_node(chunk_bytes)
    victim = max(by_node, key=lambda n: (by_node[n], -n))
    event = cluster.fail_node(victim)
    executed += cluster.run_workload(queries[half:], batch_size=3)

    got_m = [e.matches for e in executed]
    summ = workload_summary(executed)
    print(f"replicated chunks before kill: {replicated}")
    print(f"killed node {victim}: readmits={event['failover_readmits']} "
          f"from_replica={event['recovery_bytes_from_replica']} "
          f"from_raw={event['recovery_bytes_from_raw']} "
          f"recovery_s={event['recovery_s']:.4f}")
    print(f"summary failover_readmits={summ.get('failover_readmits')} "
          f"replica_hits={summ.get('replica_hits')}")
    if replicated <= 0:
        print("FAIL: no chunk held >1 replica before the kill — the "
              "replication round never promoted", file=sys.stderr)
        return 1
    if summ.get("failover_readmits", 0) <= 0:
        print("FAIL: failover_readmits == 0 — the recovery path is dead",
              file=sys.stderr)
        return 1
    if got_m != ref_m or sum(m or 0 for m in ref_m) <= 0:
        print("FAIL: match counts differ from the unfailed single-copy "
              "reference (stale/partial results after failover?)",
              file=sys.stderr)
        return 1
    print("OK: replicas formed, node killed and recovered, bit-identical "
          "match counts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
