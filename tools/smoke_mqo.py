#!/usr/bin/env python
"""CI smoke gate for cross-batch MQO + the versioned result cache
(ISSUE 6 satellite).

Runs a seeded Zipf repeat workload twice through a batched cluster —
once with ``mqo="off"``/``result_cache="off"`` (the seed-parity
reference) and once with both tiers on — and fails unless the optimized
run

  * reports ``mqo_shared_hits > 0`` — catches a silent dedup bypass
    where ``execute_batch`` degenerates to the per-query loop (every
    repeated join task would quietly re-execute);
  * reports ``result_cache_hits > 0`` — catches a dead result tier
    (version bumping on every batch, key canonicalization drift, or a
    lookup that never runs);
  * returns per-query match counts bit-identical to the reference —
    catches a fan-out or stale-entry path serving wrong counts.

Usage (both CI tier-1 jobs run exactly this; the mesh job passes
``--backend jax_mesh``):

    PYTHONPATH=src python tools/smoke_mqo.py [--backend jax_mesh]
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def main(argv=None) -> int:
    """Run the smoke workload; returns a process exit code."""
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_geo_files
    from repro.core.cluster import RawArrayCluster, workload_summary
    from repro.core.workload import zipf_workload

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="simulated",
                    choices=("simulated", "jax_mesh"))
    args = ap.parse_args(argv)

    files = make_geo_files(n_files=3, n_seeds=120, clones_per_seed=20,
                           seed=5)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="smoke_mqo_"),
                                  "csv", n_nodes=4)
    # Budget covers the dataset: residency stabilizes, so repeat batches
    # must be served from the result tier once the version stops bumping.
    budget = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    reader = FileReader(catalog, data)
    queries = zipf_workload(catalog.domain, n_queries=24, n_templates=6,
                            s=1.1, eps=300, field_frac=0.4, seed=7)

    def build(mqo: str, rc: str) -> RawArrayCluster:
        return RawArrayCluster(catalog, reader, 4, budget // 4,
                               policy="cost", min_cells=512,
                               join_backend="pallas",
                               backend=args.backend,
                               mqo=mqo, result_cache=rc)

    reference = build("off", "off").run_workload(queries, batch_size=8)
    optimized_cluster = build("on", "on")
    optimized = optimized_cluster.run_workload(queries, batch_size=8)
    ref_m = [e.matches for e in reference]
    opt_m = [e.matches for e in optimized]
    summ = workload_summary(optimized)
    stats = optimized_cluster.coordinator.stats
    print(f"reference matches: {ref_m}")
    print(f"optimized matches: {opt_m}")
    print(f"mqo_tasks_total={summ.get('mqo_tasks_total')} "
          f"mqo_tasks_executed={summ.get('mqo_tasks_executed')} "
          f"mqo_shared_hits={summ.get('mqo_shared_hits')} "
          f"result_cache_hits={stats['result_cache_hits']} "
          f"result_cache_misses={stats['result_cache_misses']} "
          f"planner_invocations="
          f"{optimized_cluster.coordinator.planner_invocations}")
    if summ.get("mqo_shared_hits", 0) <= 0:
        print("FAIL: no shared task hits — cross-batch dedup is being "
              "bypassed", file=sys.stderr)
        return 1
    if stats["result_cache_hits"] <= 0:
        print("FAIL: no result-cache hits — repeat queries are being "
              "re-planned", file=sys.stderr)
        return 1
    if opt_m != ref_m or sum(m or 0 for m in ref_m) <= 0:
        print("FAIL: optimized match counts differ from the reference "
              "(bad fan-out or stale result served?)", file=sys.stderr)
        return 1
    print("OK: shared-task + result-cache hits with bit-identical match "
          "counts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
