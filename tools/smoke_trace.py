#!/usr/bin/env python
"""CI smoke gate for the unified telemetry layer (ISSUE 8 satellite).

Runs a seeded Zipf workload through a batched cluster with
``telemetry="on"`` (reuse + MQO + result cache + hot replication all
engaged so every counter family records) and fails unless

  * the exported ``.trace.json`` is well-formed Chrome trace-event JSON
    (a ``traceEvents`` list of ``ph="X"`` spans with ``ts``/``dur``) —
    catches an exporter that Perfetto/``chrome://tracing`` would reject;
  * the root ``workload`` span's direct children cover >90% of its
    wall-clock — catches planner/backend phases silently escaping the
    span stack (orphaned parents, begin without end);
  * the live registry's ``as_summary()`` is key-for-key, value-for-value
    equal to ``workload_summary(executed)`` — catches an execution path
    that constructs an ``ExecutedQuery`` without recording it, or a
    registry aggregation that drifts from the legacy fold.

Usage (both CI tier-1 jobs run exactly this; the mesh job passes
``--backend jax_mesh``):

    PYTHONPATH=src python tools/smoke_trace.py [--backend jax_mesh]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def main(argv=None) -> int:
    """Run the smoke workload; returns a process exit code."""
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_geo_files
    from repro.core.cluster import RawArrayCluster, workload_summary
    from repro.core.workload import zipf_workload

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="simulated",
                    choices=("simulated", "jax_mesh"))
    args = ap.parse_args(argv)

    files = make_geo_files(n_files=3, n_seeds=120, clones_per_seed=20,
                           seed=5)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="smoke_trace_"),
                                  "csv", n_nodes=4)
    budget = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    reader = FileReader(catalog, data)
    queries = zipf_workload(catalog.domain, n_queries=24, n_templates=6,
                            s=1.1, eps=300, field_frac=0.4, seed=7)

    cluster = RawArrayCluster(catalog, reader, 4, budget // 4,
                              policy="cost", min_cells=512,
                              join_backend="pallas",
                              backend=args.backend,
                              reuse="on", mqo="on", result_cache="on",
                              replication="hot", telemetry="on")
    executed = cluster.run_workload(queries, batch_size=8)

    # -- 1. Chrome trace-event JSON shape ------------------------------
    trace_path = os.path.join(tempfile.mkdtemp(prefix="smoke_trace_out_"),
                              "workload.trace.json")
    cluster.export_trace(trace_path)
    with open(trace_path) as fh:
        doc = json.load(fh)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        print("FAIL: exported trace has no traceEvents list",
              file=sys.stderr)
        return 1
    complete = [e for e in events if e.get("ph") == "X"]
    bad = [e for e in complete
           if not (isinstance(e.get("ts"), (int, float))
                   and isinstance(e.get("dur"), (int, float))
                   and e.get("dur") >= 0 and e.get("name"))]
    if not complete or bad:
        print(f"FAIL: malformed complete events in trace: {bad[:3]}",
              file=sys.stderr)
        return 1

    # -- 2. Root-span coverage -----------------------------------------
    spans = cluster.telemetry.tracer.spans
    roots = [s for s in spans if s.parent_id is None]
    if len(roots) != 1 or roots[0].name != "workload":
        print(f"FAIL: expected one root 'workload' span, got "
              f"{[(s.name, s.parent_id) for s in roots]}", file=sys.stderr)
        return 1
    root = roots[0]
    children = [s for s in spans if s.parent_id == root.span_id]
    coverage = (sum(c.duration_s for c in children) / root.duration_s
                if root.duration_s else 0.0)
    print(f"spans={len(spans)} trace_events={len(events)} "
          f"root_duration_s={root.duration_s:.4f} coverage={coverage:.4f}")
    if coverage <= 0.90:
        print(f"FAIL: direct children of the workload span cover only "
              f"{coverage:.1%} of its wall-clock (phases escaping the "
              f"span stack?)", file=sys.stderr)
        return 1

    # -- 3. Live registry == workload_summary --------------------------
    legacy = workload_summary(executed)
    live = cluster.telemetry.registry.as_summary()
    missing = [k for k in legacy if k not in live]
    drift = {k: (legacy[k], live[k]) for k in legacy
             if k in live and live[k] != legacy[k]}
    extra = [k for k in live if k not in legacy]
    if missing or drift or extra:
        print(f"FAIL: registry/summary divergence — missing={missing} "
              f"drift={drift} extra={extra}", file=sys.stderr)
        return 1
    engaged = [k for k in ("reuse_hits", "mqo_shared_hits",
                           "result_cache_hits", "replica_hits")
               if legacy.get(k, 0) > 0]
    print(f"summary keys={len(legacy)} engaged_counters={engaged}")
    if len(engaged) < 3:
        print(f"FAIL: mixed workload did not engage enough counter "
              f"families (got {engaged}) — smoke lost its teeth",
              file=sys.stderr)
        return 1
    print("OK: valid Chrome trace, >90% span coverage, registry totals "
          "match workload_summary")
    return 0


if __name__ == "__main__":
    sys.exit(main())
