#!/usr/bin/env python
"""Render a telemetry trace as a per-phase / per-node text breakdown.

Runs a seeded Zipf workload (the ``smoke_mqo`` recipe) through a
``telemetry="on"`` cluster, prints

  * a **per-phase** table — one row per span name (``plan.scan``,
    ``policy.evict``, ``dispatch``, ...) with call count, total/mean
    duration, and share of the root ``workload`` span's wall-clock;
  * a **per-node** table — simjoin work and cache health by node, read
    from the registry's ``device.*`` / ``cache.budget_utilization``
    gauges and the per-node span args;
  * the registry summary (every ``workload_summary`` counter, straight
    from the live registry),

and writes the Chrome trace-event JSON artifact (default
``workload.trace.json``) for Perfetto / ``chrome://tracing``.

Usage:

    PYTHONPATH=src python tools/trace_report.py \
        [--backend jax_mesh] [--out workload.trace.json]
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from collections import defaultdict


def phase_table(spans) -> str:
    """Format the per-phase breakdown table from a list of spans."""
    roots = [s for s in spans if s.parent_id is None]
    wall = sum(s.duration_s for s in roots) or 1.0
    agg = defaultdict(lambda: [0, 0.0])  # name -> [count, total_s]
    for s in spans:
        agg[s.name][0] += 1
        agg[s.name][1] += s.duration_s
    lines = [f"{'phase':<18}{'count':>7}{'total_s':>10}{'mean_ms':>10}"
             f"{'% wall':>8}"]
    for name, (count, total) in sorted(agg.items(),
                                       key=lambda kv: -kv[1][1]):
        lines.append(f"{name:<18}{count:>7}{total:>10.4f}"
                     f"{1e3 * total / count:>10.3f}"
                     f"{100.0 * total / wall:>7.1f}%")
    return "\n".join(lines)


def node_table(spans, registry) -> str:
    """Format the per-node breakdown from span args and gauges."""
    per_node = defaultdict(lambda: [0, 0.0])  # node -> [spans, total_s]
    for s in spans:
        node = s.args.get("node")
        if node is not None:
            per_node[node][0] += 1
            per_node[node][1] += s.duration_s
    util = {}
    for g in registry.as_dict().get("gauges", []):
        if g["name"] == "cache.budget_utilization":
            util[g["labels"].get("node")] = g["value"]
    nodes = sorted(set(per_node) | set(util))
    lines = [f"{'node':<6}{'spans':>7}{'span_s':>10}{'budget_util':>13}"]
    for n in nodes:
        count, total = per_node.get(n, (0, 0.0))
        u = util.get(n)
        lines.append(f"{n!s:<6}{count:>7}{total:>10.4f}"
                     f"{('%.3f' % u if u is not None else '-'):>13}")
    return "\n".join(lines) if nodes else "(no per-node spans or gauges)"


def main(argv=None) -> int:
    """Run the workload, print the report, write the trace artifact."""
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_geo_files
    from repro.core.cluster import RawArrayCluster
    from repro.core.workload import zipf_workload

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="simulated",
                    choices=("simulated", "jax_mesh"))
    ap.add_argument("--out", default="workload.trace.json",
                    help="path for the Chrome trace-event JSON artifact")
    args = ap.parse_args(argv)

    files = make_geo_files(n_files=3, n_seeds=120, clones_per_seed=20,
                           seed=5)
    catalog, data = build_catalog(files,
                                  tempfile.mkdtemp(prefix="trace_report_"),
                                  "csv", n_nodes=4)
    budget = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    reader = FileReader(catalog, data)
    queries = zipf_workload(catalog.domain, n_queries=24, n_templates=6,
                            s=1.1, eps=300, field_frac=0.4, seed=7)
    cluster = RawArrayCluster(catalog, reader, 4, budget // 4,
                              policy="cost", min_cells=512,
                              join_backend="pallas", backend=args.backend,
                              reuse="on", mqo="on", result_cache="on",
                              replication="hot", telemetry="on")
    executed = cluster.run_workload(queries, batch_size=8)

    spans = cluster.telemetry.tracer.spans
    print(f"== per-phase breakdown ({len(spans)} spans, "
          f"{len(executed)} queries, backend={args.backend}) ==")
    print(phase_table(spans))
    print("\n== per-node breakdown ==")
    print(node_table(spans, cluster.telemetry.registry))
    print("\n== registry summary ==")
    for k, v in cluster.telemetry.registry.as_summary().items():
        print(f"  {k} = {v:g}")
    path = cluster.export_trace(args.out)
    print(f"\nwrote Chrome trace artifact: {path} "
          f"(load in Perfetto or chrome://tracing)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
